"""Tests for the first-class PackedWeight pytree + unified ExecPolicy API:
registration, whole-tree packing, structural sharding rules, checkpoint
round-trip onto a different mesh, and the deprecation shims."""

import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import sparse_linear as sl
from repro.core.sparse_linear import DEFAULT_POLICY, ExecPolicy, resolve_policy
from repro.core.sparsity import PackedWeight, SparsityConfig, Static
from repro.models.layers import apply_linear, init_linear

CFG = SparsityConfig(2, 16)


def _pw(key=0, o=16, k=64, cfg=CFG):
    params = sl.init_sparse(jax.random.PRNGKey(key), k, o, cfg)
    return params, sl.pack_params(params, cfg)


# ---------------------------------------------------------------------------
# Pytree registration
# ---------------------------------------------------------------------------

def test_packed_weight_is_registered_pytree():
    _, pw = _pw()
    leaves, treedef = jax.tree_util.tree_flatten(pw)
    assert len(leaves) == 2
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, PackedWeight)
    assert rebuilt.cfg == pw.cfg
    assert rebuilt.dense_shape == pw.dense_shape
    assert rebuilt.layout == pw.layout


def test_packed_weight_tree_map_keeps_aux():
    _, pw = _pw()
    doubled = jax.tree.map(lambda a: a * 2, pw)
    assert isinstance(doubled, PackedWeight)
    assert doubled.cfg == pw.cfg
    np.testing.assert_array_equal(np.asarray(doubled.indices),
                                  np.asarray(pw.indices) * 2)


def test_packed_weight_key_paths():
    _, pw = _pw()
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(pw)[0]]
    assert paths == [".values", ".indices"]


def test_packed_weight_static_aux_under_jit():
    params, pw = _pw()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))

    @jax.jit
    def f(pw_, x_):
        # aux data is static: visible at trace time
        assert pw_.cfg == CFG and pw_.dense_shape == (16, 64)
        return sl.apply(pw_, x_, ExecPolicy(mode="packed"))

    np.testing.assert_allclose(np.asarray(f(pw, x)),
                               np.asarray(sl.apply_masked(params, x, CFG)),
                               rtol=1e-3, atol=1e-3)


def test_packed_weight_to_dense_roundtrip():
    params, pw = _pw()
    np.testing.assert_allclose(
        np.asarray(pw.to_dense()),
        np.asarray(jnp.where(params["w"] != 0, params["w"], 0.0)),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# ExecPolicy
# ---------------------------------------------------------------------------

def test_exec_policy_hashable_and_normalized():
    a = ExecPolicy(mode="packed", backend="auto", cfg_overrides={"k": 2})
    b = ExecPolicy(mode="packed", backend="auto", cfg_overrides=(("k", 2),))
    assert a == b and hash(a) == hash(b)
    assert a.resolve_cfg(SparsityConfig(4, 32, 1)) == SparsityConfig(4, 32, 2)
    with pytest.raises(ValueError):
        ExecPolicy(mode="bogus")


def test_resolve_policy_legacy_kwargs():
    assert resolve_policy(None, None, None) is DEFAULT_POLICY
    p = resolve_policy(None, "packed", "auto")
    assert p == ExecPolicy(mode="packed", backend="auto")
    with pytest.raises(ValueError):
        resolve_policy(ExecPolicy(), "packed", None)


def test_cfg_override_k_reconfigures_packed_apply():
    """An n_effective-preserving k override reinterprets a packed weight as
    k passes (paper §II-B) without changing numerics."""
    cfg = SparsityConfig(4, 32, 1)
    params = sl.init_sparse(jax.random.PRNGKey(0), 64, 16, cfg)
    pw = sl.pack_params(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    base = sl.apply(pw, x, ExecPolicy(mode="packed"))
    recfg = sl.apply(pw, x, ExecPolicy(mode="packed",
                                       cfg_overrides={"n": 2, "k": 2}))
    np.testing.assert_allclose(np.asarray(base), np.asarray(recfg),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):  # layout-changing override is rejected
        sl.apply(pw, x, ExecPolicy(mode="packed", cfg_overrides={"n": 8}))


# ---------------------------------------------------------------------------
# init_linear metadata + pack_tree
# ---------------------------------------------------------------------------

def test_init_linear_stores_full_sparsity_config():
    # 256 // PRODUCTION_TP = 16 = the requested group, so choose_group keeps
    # the 4:16 pattern and init_linear re-expresses it as the requested k=2
    p = init_linear(jax.random.PRNGKey(0), 256, 32,
                    sparse=SparsityConfig(2, 16, 2))
    cfg = p["sparsity"].value
    assert isinstance(cfg, SparsityConfig)
    assert cfg.k == 2 and cfg.n_effective == 4
    assert "_sparse_m" not in p


def test_pack_tree_emits_packed_weights_including_stacked():
    from repro.launch.pack_tree import pack_tree

    cfg = SparsityConfig(2, 16)
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))  # stacked L=3
    tree = {"layers": {"mlp": {"gate": {"w": w, "sparsity": Static(cfg)}}},
            "norm": {"scale": jnp.ones((8,))}}
    packed = pack_tree(tree)
    pw = packed["layers"]["mlp"]["gate"]
    assert isinstance(pw, PackedWeight)
    assert pw.dense_shape == (8, 32)           # per-layer shape
    assert pw.stack_dims == (3,)
    assert pw.values.shape == (3, 8, 2, 2)     # (L, O, G, Ne)
    # dense weights untouched
    np.testing.assert_array_equal(np.asarray(packed["norm"]["scale"]),
                                  np.asarray(tree["norm"]["scale"]))
    # stacked pack == per-slice pack
    per = sl.pack_params({"w": w[1]}, cfg)
    np.testing.assert_array_equal(np.asarray(pw.values[1]),
                                  np.asarray(per.values))


# ---------------------------------------------------------------------------
# Structural sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_structural_for_packed_weights():
    from repro.sharding.plan import ShardingPlan

    cfg = SparsityConfig(2, 16)
    def lin(key):
        return init_linear(jax.random.PRNGKey(key), 64, 32, sparse=cfg)
    from repro.launch.pack_tree import pack_tree
    tree = pack_tree({"mlp": {"gate": lin(0), "down": lin(1)},
                      "attn": {"wq": lin(2)}})
    specs = ShardingPlan().param_specs(tree)
    assert isinstance(specs["mlp"]["gate"], PackedWeight)
    assert specs["mlp"]["gate"].values == P("model", None, None)    # col
    assert specs["mlp"]["down"].values == P(None, "model", None)    # row
    assert specs["attn"]["wq"].values == P("model", None, None)     # col
    # kv-replication classifies structurally too
    tree2 = pack_tree({"attn": {"wk": lin(3)}})
    specs2 = ShardingPlan(attn_kv_replicated=True).param_specs(tree2)
    assert specs2["attn"]["wk"].values == P(None, None, None)


# ---------------------------------------------------------------------------
# Checkpoint round-trip (elastic restore onto a different mesh)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_packed_model_different_mesh():
    """pack_tree -> save -> restore onto a (different) mesh via shardings ->
    decode step produces identical logits, SparsityConfig.k included."""
    from repro.configs.base import get_arch
    from repro.launch.pack_tree import pack_tree, pack_tree_shapes
    from repro.models.families import build_model
    from repro.sharding import partitioning as part
    from repro.train import checkpoint as ckpt

    arch = get_arch("stablelm_3b").reduced()
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    packed = pack_tree(params)

    # saved-side: unsharded host save
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(packed, d, 7)

        # restoring process: fresh template from shapes only, placed on a
        # mesh the saver never saw
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        template = pack_tree_shapes(model, pshapes)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        from repro.sharding.plan import ShardingPlan
        shardings = part.shardings_for(
            mesh, ShardingPlan().param_specs(template))
        restored = ckpt.restore(template, d, 7, shardings=shardings)

    for a, b in zip(jax.tree_util.tree_leaves(packed),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    state = model.init_decode_state(2, 16, dtype=jnp.float32)
    toks = jnp.zeros((2, 1), jnp.int32)
    pol = ExecPolicy(mode="packed")
    l0, _ = model.decode_step(packed, state, toks, policy=pol)
    l1, _ = model.decode_step(restored, state, toks, policy=pol)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_checkpoint_manifest_is_authoritative_for_sparsity():
    """A stale template (wrong k) is corrected from the manifest on restore."""
    from repro.train import checkpoint as ckpt

    cfg = SparsityConfig(1, 16, 2)
    params = sl.init_sparse(jax.random.PRNGKey(0), 32, 8, cfg)
    pw = sl.pack_params(params, cfg)
    tree = {"lin": pw, "meta": Static(cfg)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(tree, d, 1)
        stale = {"lin": pw.replace(cfg=SparsityConfig(2, 16, 1)),
                 "meta": Static(SparsityConfig(2, 16, 1))}
        restored = ckpt.restore(stale, d, 1)
    assert restored["lin"].cfg == cfg
    assert restored["meta"].value == cfg


# ---------------------------------------------------------------------------
# Legacy dict conventions: shims dropped after one release; every consumer
# now fails with a clear ValueError pointing at pack_tree / init_linear.
# ---------------------------------------------------------------------------

def test_legacy_packed_dict_rejected_everywhere():
    params, pw = _pw()
    legacy = {"values": pw.values, "indices": pw.indices,
              "shape": Static(pw.dense_shape),
              "_sparse_m": Static(CFG.m), "_sparse_n": Static(CFG.n)}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    with pytest.raises(ValueError, match="pack_tree"):
        apply_linear(legacy, x, mode="packed")
    with pytest.raises(ValueError, match="pack_tree"):
        sl.apply_packed(legacy, x, CFG)
    from repro.launch.pack_tree import pack_tree
    with pytest.raises(ValueError, match="pack_tree"):
        pack_tree({"mlp": {"gate": legacy}})
    from repro import tune
    with pytest.raises(ValueError, match="pack_tree"):
        tune.autotune_packed_tree({"mlp": {"gate": legacy}}, 4)
    from repro.sharding.plan import ShardingPlan
    with pytest.raises(ValueError, match="pack_tree"):
        ShardingPlan().param_specs({"mlp": {"gate": legacy}})


def test_legacy_masked_metadata_rejected():
    params, _ = _pw()
    legacy = {"w": params["w"], "_sparse_m": Static(CFG.m),
              "_sparse_n": Static(CFG.n)}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    with pytest.raises(ValueError, match="init_linear"):
        apply_linear(legacy, x)
    # non-dict / non-PackedWeight params keep a TypeError
    with pytest.raises(TypeError, match="PackedWeight"):
        sl.apply_packed(params["w"], x)


# ---------------------------------------------------------------------------
# Block layout (two-level ahead-of-time packing)
# ---------------------------------------------------------------------------

def _block_pw(key=0, o=32, k=64, cfg=CFG, block_r=8):
    """A dense N:M weight and its two-level block packing."""
    from repro.core.sparsity import pack_block, random_sparse_dense

    w = jnp.asarray(random_sparse_dense(np.random.default_rng(key), o, k, cfg))
    return w, pack_block(w, cfg, block_r=block_r)


def test_pack_block_geometry_and_pytree():
    from repro.core.sparsity import unpack_block

    w, pw = _block_pw()
    assert pw.layout == "block"
    br, a_max = pw.block_geom
    assert br == 8
    assert pw.values.shape == (4, a_max, 8, CFG.n_effective)
    assert pw.indices.shape == pw.values.shape
    assert pw.active_groups.shape == (4, a_max)
    # three traced children; aux (incl. geometry) survives a flatten cycle
    leaves, treedef = jax.tree_util.tree_flatten(pw)
    assert len(leaves) == 3
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.block_geom == pw.block_geom
    assert rebuilt.layout == "block" and rebuilt.cfg == CFG
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(pw)[0]]
    assert paths == [".values", ".indices", ".active_groups"]
    # lossless for a pattern-satisfying weight
    np.testing.assert_array_equal(np.asarray(pw.to_dense()), np.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(unpack_block(pw.active_groups, pw.values, pw.indices,
                                CFG, pw.dense_shape)),
        np.asarray(w))


def test_block_apply_parity_vs_ref_oracle():
    """pack_block -> apply matches the kernels/ref.block_spmm_ref oracle and
    the dense matmul, on the reference and (interpret) Pallas backends."""
    from repro.kernels.ref import block_spmm_ref

    w, pw = _block_pw()
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
    want_oracle = np.asarray(block_spmm_ref(
        pw.active_groups, pw.values, pw.indices, x.T, CFG, 32).T)
    want_dense = np.asarray(x @ w.T)
    for backend in ("reference", "block_spmm"):
        y = sl.apply(pw, x, ExecPolicy(mode="packed", backend=backend))
        np.testing.assert_allclose(np.asarray(y), want_oracle,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y), want_dense,
                                   rtol=1e-4, atol=1e-4)


def test_block_matches_xwT_path_through_checkpoint():
    """Acceptance regression: a block-layout PackedWeight survives
    pack -> apply -> checkpoint -> elastic restore with outputs identical
    (within tolerance) to the xwT path."""
    import tempfile

    from repro.train import checkpoint as ckpt

    w, pw_block = _block_pw()
    pw_xwT = sl.pack_params({"w": w}, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    pol = ExecPolicy(mode="packed")
    y_xwT = np.asarray(sl.apply(pw_xwT, x, pol))
    y_block = np.asarray(sl.apply(pw_block, x, pol))
    np.testing.assert_allclose(y_block, y_xwT, rtol=1e-5, atol=1e-5)

    with tempfile.TemporaryDirectory() as d:
        ckpt.save({"lin": pw_block}, d, 1)
        # elastic restore: fresh shape-only template (as a restarted process
        # would build), manifest is authoritative for the aux
        template = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            {"lin": pw_block})
        restored = ckpt.restore(template, d, 1)["lin"]
    assert restored.layout == "block"
    assert restored.block_geom == pw_block.block_geom
    assert restored.cfg == CFG
    np.testing.assert_array_equal(np.asarray(restored.active_groups),
                                  np.asarray(pw_block.active_groups))
    np.testing.assert_array_equal(np.asarray(sl.apply(restored, x, pol)),
                                  y_block)


def test_block_param_specs_structural():
    from repro.launch.pack_tree import pack_tree
    from repro.sharding.plan import ShardingPlan

    cfg = SparsityConfig(2, 16)
    def lin(key):
        w = jax.random.normal(jax.random.PRNGKey(key), (32, 64))
        return {"w": w, "sparsity": Static(cfg)}
    tree = pack_tree({"mlp": {"gate": lin(0), "down": lin(1)}},
                     layout="block")
    assert tree["mlp"]["gate"].layout == "block"
    specs = ShardingPlan().param_specs(tree)
    # col-parallel shards the row-block axis of all three children
    assert specs["mlp"]["gate"].values == P("model", None, None, None)
    assert specs["mlp"]["gate"].active_groups == P("model", None)
    # row-parallel needs active-group renumbering -> replicated for now
    assert specs["mlp"]["down"].values == P(None, None, None, None)
    assert specs["mlp"]["down"].active_groups == P(None, None)


def test_pack_tree_block_stacked_scan_slices():
    """Stacked block packing shares a_max across the stack and scan-style
    layer slicing reproduces the per-layer packing."""
    from repro.core.sparsity import pack_block
    from repro.launch.pack_tree import pack_tree

    cfg = SparsityConfig(2, 16)
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))  # stacked L=3
    tree = pack_tree({"layers": {"w": w, "sparsity": Static(cfg)}},
                     layout="block")
    pw = tree["layers"]
    assert pw.layout == "block" and pw.stack_dims == (3,)
    assert pw.dense_shape == (8, 32)
    br, a_max = pw.block_geom
    assert pw.values.shape == (3, 8 // br, a_max, br, cfg.n_effective)
    # slicing the layer axis (what lax.scan does) == packing that slice with
    # the shared a_max
    sliced = jax.tree.map(lambda a: a[1], pw)
    per = pack_block(w[1], cfg, block_r=br, a_max=a_max)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
    pol = ExecPolicy(mode="packed")
    np.testing.assert_allclose(
        np.asarray(sl.apply(sliced, x, pol)),
        np.asarray(sl.apply(per, x, pol)), rtol=1e-5, atol=1e-5)
    # stacked to_dense restores the stack dims (regression: used to crash)
    np.testing.assert_allclose(np.asarray(pw.to_dense()[1]),
                               np.asarray(per.to_dense()),
                               rtol=1e-6, atol=1e-6)
    assert pw.to_dense().shape == (3, 8, 32)


def test_autotune_packed_tree_slices_stacked_block(tmp_path):
    """A scan-stacked block tree pre-tunes by slicing one layer off (the
    decode step applies 2-D slices), instead of erroring on 5-D operands."""
    from repro import tune
    from repro.core.sparsity import pack_block_stacked

    cfg = SparsityConfig(2, 16)
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))
    pw = pack_block_stacked(w, cfg)
    cache = tune.TuneCache(path=str(tmp_path / "cache.json"))
    results = tune.autotune_packed_tree(
        {"layers": pw}, 4, persist=False, cache=cache,
        max_measure=1, warmup=1, iters=1)
    (res,) = results.values()
    assert res.problem.op == "xwT_block"
    assert any(c.status == "measured" for c in res.candidates)


def test_pack_block_a_max_validation_and_padding():
    from repro.core.sparsity import (pack_block, pack_block_stacked,
                                     random_sparse_dense)

    w = jnp.asarray(random_sparse_dense(np.random.default_rng(0), 8, 32,
                                        CFG))                  # G = 2
    # a_max beyond the group count pads with inactive slots (useful when
    # matching an existing checkpoint's geometry) — still lossless
    pw = pack_block(w, CFG, block_r=8, a_max=5)
    assert pw.block_geom == (8, 5)
    assert pw.values.shape == (1, 5, 8, CFG.n_effective)
    np.testing.assert_array_equal(np.asarray(pw.to_dense()), np.asarray(w))
    # an undersized explicit a_max raises — including on the stacked path,
    # whose per-slice packers run under vmap and cannot check it themselves
    # (regression: used to silently drop weights from the densest slice)
    ws = jnp.zeros((2, 8, 32)).at[0, 0, 0].set(1.0).at[0, 0, 16].set(2.0)
    with pytest.raises(ValueError, match="active groups"):
        pack_block_stacked(ws, CFG, block_r=8, a_max=1)
    with pytest.raises(ValueError, match="active groups"):
        pack_block(ws[0], CFG, block_r=8, a_max=1)


def test_block_auto_dispatch_resolves_block_spmm(tmp_path):
    """backend='auto' can resolve a block-layout weight to the block_spmm
    kernel on CPU: forced cache entries dispatch it (numerics unchanged) and
    the autotuner measures it as a first-class, dispatchable candidate."""
    from repro import tune

    cache = tune.TuneCache(path=str(tmp_path / "cache.json"))
    tune.set_default_cache(cache)
    try:
        w, pw = _block_pw()
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
        p = tune.Problem.for_xwT_block(x.shape, pw, x.dtype)
        assert f"b{pw.block_geom[0]}x{pw.block_geom[1]}" in \
            tune.problem_key(p)
        cache.put(p, tune.TunedConfig(backend="block_spmm",
                                      params={"cd_block": 8}))
        y = jax.jit(lambda pw_, x_: sl.apply(
            pw_, x_, ExecPolicy(mode="packed", backend="auto")))(pw, x)
        np.testing.assert_allclose(
            np.asarray(y),
            np.asarray(sl.apply(pw, x, ExecPolicy(mode="packed"))),
            rtol=1e-5, atol=1e-5)

        res = tune.autotune_xwT_block(x, pw, cache=cache, persist=False,
                                      max_measure=2, warmup=1, iters=1)
        measured = {c.backend for c in res.candidates
                    if c.status == "measured"}
        assert "block_spmm" in measured   # dispatchable, not measure-only
        assert res.best.backend in measured
    finally:
        tune.set_default_cache(None)


def test_autotune_packed_tree_handles_block_layout(tmp_path):
    from repro import tune

    w, pw = _block_pw()
    cache = tune.TuneCache(path=str(tmp_path / "cache.json"))
    results = tune.autotune_packed_tree(
        {"mlp": {"gate": pw, "up": pw}}, 4, persist=False, cache=cache,
        max_measure=1, warmup=1, iters=1)
    assert len(results) == 1   # deduped by (O, K, pattern, block geometry)
    (res,) = results.values()
    assert res.problem.op == "xwT_block"
    assert (res.problem.block_r, res.problem.a_max) == pw.block_geom


def test_unknown_layout_tag_rejected():
    """The constructor rejects unknown tags, and ops keeps a clear
    ValueError (not the old 'lands later' NotImplementedError) for a forged
    layout that slips past it."""
    from repro.kernels import ops

    _, pw = _pw()
    with pytest.raises(ValueError, match="unknown layout"):
        PackedWeight(pw.values, pw.indices, cfg=CFG, dense_shape=(16, 64),
                     layout="bogus")
    forged = object.__new__(PackedWeight)
    forged.values, forged.indices = pw.values, pw.indices
    forged.cfg, forged.dense_shape = CFG, (16, 64)
    forged.layout, forged.active_groups, forged.block_geom = \
        "bogus", None, None
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    with pytest.raises(ValueError, match="unknown PackedWeight layout"):
        ops.demm_matmul_packed(x, forged)


def test_autotune_packed_tree_keys_off_type(tmp_path):
    from repro import tune

    cfg = SparsityConfig(2, 16)
    params = sl.init_sparse(jax.random.PRNGKey(0), 32, 16, cfg)
    pw = sl.pack_params(params, cfg)
    cache = tune.TuneCache(path=str(tmp_path / "cache.json"))
    results = tune.autotune_packed_tree(
        {"mlp": {"gate": pw, "up": pw}}, 4, persist=False, cache=cache,
        max_measure=1, warmup=1, iters=1)
    assert len(results) == 1  # deduped by (O, K, pattern) from static aux
    (res,) = results.values()
    assert res.problem.sparsity == (cfg.n, cfg.m, cfg.k)
