"""Distributed packed serving: renumbering, ShardingPlan, TP/PP/DP engines.

Single-process tests cover the host-side pieces (the per-shard group
renumbering round-trip, plan classification/serialization, the engine
factory, the replica router).  The genuinely multi-device paths — TP=2
token identity for both packed layouts with *actually sharded* row-parallel
weights, PP=2 pipelined decode, the sharded paged arena under preemption —
run in subprocesses with forced host devices (tests/helpers.py), because
the device count must be set before jax imports.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.sparsity import (
    LAYOUT_BLOCK,
    PackedWeight,
    SparsityConfig,
    pack_block,
    shard_packed_row_parallel,
    shard_slice,
    unshard_packed,
)

from helpers import run_with_devices

CFG = SparsityConfig(2, 8, 1)


def _dense(rng, o, k, cfg=CFG):
    w = rng.standard_normal((o, k)).astype(np.float32)
    g = k // cfg.m
    m = np.zeros((o, g, cfg.m), np.float32)
    for r in range(o):
        for gi in range(g):
            m[r, gi, rng.choice(cfg.m, cfg.n, replace=False)] = 1
    return jnp.asarray((w.reshape(o, g, cfg.m) * m).reshape(o, k))


# ---------------------------------------------------------------------------
# Renumbering pass (host-side, no mesh needed)
# ---------------------------------------------------------------------------

class TestRenumbering:
    def test_xwT_round_trip(self):
        rng = np.random.default_rng(0)
        pw = PackedWeight.from_dense(_dense(rng, 16, 64), CFG)
        sh = shard_packed_row_parallel(pw, 4)
        assert sh.shard_axis == "model" and sh.shards == 4
        assert sh.values.shape[0] == 4              # shard dim leads
        np.testing.assert_allclose(
            np.asarray(unshard_packed(sh).to_dense()),
            np.asarray(pw.to_dense()))

    def test_block_round_trip_renumbers_groups(self):
        rng = np.random.default_rng(1)
        pw = pack_block(_dense(rng, 16, 64), CFG, block_r=8)
        sh = shard_packed_row_parallel(pw, 2)
        g_local = pw.groups // 2
        # every surviving group id is locally renumbered into [0, G/2)
        ag = np.asarray(sh.active_groups)
        assert ag.min() >= 0 and ag.max() < g_local
        np.testing.assert_allclose(
            np.asarray(unshard_packed(sh).to_dense()),
            np.asarray(pw.to_dense()))

    def test_shard_slice_is_local(self):
        rng = np.random.default_rng(2)
        pw = pack_block(_dense(rng, 16, 64), CFG, block_r=8)
        sh = shard_packed_row_parallel(pw, 2)
        loc = shard_slice(sh, 0)
        assert loc.shard_axis is None and loc.shards == 2
        assert loc.dense_shape == (16, 32)

    def test_matmul_identity_without_mesh(self):
        # no matching mesh installed -> the sequential fallback must still
        # reproduce the unsharded packed matmul exactly
        from repro.kernels.ops import demm_matmul_packed
        rng = np.random.default_rng(3)
        for layout_pack in (
                lambda w: PackedWeight.from_dense(w, CFG),
                lambda w: pack_block(w, CFG, block_r=8)):
            pw = layout_pack(_dense(rng, 16, 64))
            sh = shard_packed_row_parallel(pw, 2)
            x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
            np.testing.assert_allclose(
                np.asarray(demm_matmul_packed(x, sh)),
                np.asarray(demm_matmul_packed(x, pw)), rtol=2e-5, atol=2e-5)

    def test_group_count_must_divide(self):
        rng = np.random.default_rng(4)
        pw = PackedWeight.from_dense(_dense(rng, 8, 64), CFG)   # 8 groups
        with pytest.raises(ValueError):
            shard_packed_row_parallel(pw, 3)

    def test_q8_block_rejected(self):
        from repro.quant import quantize_packed
        rng = np.random.default_rng(5)
        pw = quantize_packed(pack_block(_dense(rng, 16, 64), CFG, block_r=8))
        with pytest.raises(NotImplementedError):
            shard_packed_row_parallel(pw, 2)


# ---------------------------------------------------------------------------
# ShardingPlan
# ---------------------------------------------------------------------------

class TestShardingPlan:
    def test_kind_overrides_win(self):
        from repro.sharding.plan import ShardingPlan
        plan = ShardingPlan(tp=2, kind_overrides=(("mlp/down", "replicated"),))
        assert plan.linear_kind("mlp/down") == "replicated"
        assert plan.linear_kind("mlp/up") == "col"

    def test_renumber_params_targets_row_kinds(self):
        from repro.sharding.plan import ShardingPlan
        rng = np.random.default_rng(6)
        params = {"mlp": {"down": {"w": pack_block(_dense(rng, 16, 64), CFG,
                                                   block_r=8)},
                          "up": {"w": PackedWeight.from_dense(
                              _dense(rng, 64, 16), CFG)}}}
        out = ShardingPlan(tp=2).renumber_params(params)
        assert out["mlp"]["down"]["w"].shard_axis == "model"
        assert out["mlp"]["up"]["w"].shard_axis is None
        # replicate policy and tp=1 are both identity
        assert ShardingPlan(tp=2, renumber="replicate").renumber_params(
            params)["mlp"]["down"]["w"].shard_axis is None
        assert ShardingPlan().renumber_params(params) is params

    def test_json_round_trip(self):
        from repro.sharding.plan import ShardingPlan
        plan = ShardingPlan(tp=2, pp=2, dp=3, attn_kv_replicated=True,
                            renumber="replicate",
                            kind_overrides=(("x/w", "col"),))
        back = ShardingPlan.from_json(json.loads(json.dumps(plan.to_json())))
        assert back == plan

    def test_manifest_round_trip(self, tmp_path):
        from repro.sharding.plan import ShardingPlan
        from repro.train import checkpoint as ckpt
        rng = np.random.default_rng(7)
        plan = ShardingPlan(tp=2)
        params = plan.renumber_params(
            {"mlp": {"down": {"w": pack_block(_dense(rng, 16, 64), CFG,
                                              block_r=8)}}})
        ckpt.save(params, str(tmp_path), 5, plan=plan)
        assert ckpt.load_plan(str(tmp_path)) == plan
        restored = ckpt.restore(params, str(tmp_path), 5)
        rw = restored["mlp"]["down"]["w"]
        assert rw.shard_axis == "model" and rw.shards == 2
        np.testing.assert_allclose(
            np.asarray(unshard_packed(rw).to_dense()),
            np.asarray(unshard_packed(params["mlp"]["down"]["w"]).to_dense()))

    def test_load_plan_absent(self, tmp_path):
        from repro.train import checkpoint as ckpt
        ckpt.save({"w": jnp.zeros((2,))}, str(tmp_path), 1)   # no plan
        assert ckpt.load_plan(str(tmp_path)) is None
        assert ckpt.load_plan(str(tmp_path / "nope")) is None

    def test_policy_carries_plan_hashably(self):
        from repro.core.sparse_linear import ExecPolicy
        from repro.sharding.plan import ShardingPlan
        pol = ExecPolicy(mode="packed", backend="auto",
                         plan=ShardingPlan(tp=2))
        assert hash(pol) == hash(pol.replace())
        assert pol.plan.tp == 2

    def test_removed_shims_raise(self):
        from repro.sharding import partitioning as part
        with pytest.raises(ValueError, match="ShardingPlan"):
            part.linear_kind("mlp/down")
        with pytest.raises(ValueError, match="ShardingPlan"):
            part.param_specs({"mlp": {"down": {"w": jnp.zeros((4, 8))}}})

    def test_tune_keys_carry_shard_geometry(self):
        from repro.tune import Problem, problem_key
        rng = np.random.default_rng(8)
        pw = pack_block(_dense(rng, 16, 64), CFG, block_r=8)
        local = shard_slice(shard_packed_row_parallel(pw, 2), 0)
        k_global = problem_key(Problem.for_xwT_block((4, 64), pw,
                                                     jnp.float32))
        k_local = problem_key(Problem.for_xwT_block((4, 32), local,
                                                    jnp.float32))
        assert k_global != k_local and k_local.endswith("|s2")


# ---------------------------------------------------------------------------
# Engine factory + replica router (single device)
# ---------------------------------------------------------------------------

class TestMakeEngineAndRouter:
    def _model(self):
        from repro.configs.base import get_arch
        from repro.models.families import build_model
        cfg = get_arch("stablelm_3b").reduced()
        model = build_model(cfg)
        return cfg, model, model.init(jax.random.PRNGKey(0))

    def test_dispatch_on_config_type(self):
        from repro.paged import PagedServeConfig, PagedServeEngine
        from repro.serve import ServeConfig, ServeEngine, make_engine
        cfg, model, params = self._model()
        eng = make_engine(model, params, ServeConfig(num_slots=2, max_len=32))
        assert isinstance(eng, ServeEngine)
        peng = make_engine(model, params,
                           PagedServeConfig(num_slots=2, max_len=32))
        assert isinstance(peng, PagedServeEngine)
        with pytest.raises(TypeError):
            make_engine(model, params, object())

    def test_protocol_aliases(self):
        from repro.serve import Request, ServeConfig, make_engine
        cfg, model, params = self._model()
        eng = make_engine(model, params, ServeConfig(num_slots=2, max_len=32))
        eng.submit(Request(uid=0, prompt=np.array([3, 1, 4], np.int32),
                           max_new_tokens=2))
        assert eng.tick() >= 0          # alias for step()
        eng.drain()                     # alias for run_until_drained()
        assert len(eng.completed) == 1

    def test_router_round_robin_and_merged_metrics(self):
        from repro.serve import Request, ServeConfig, make_engine
        cfg, model, params = self._model()
        router = make_engine(model, params,
                             ServeConfig(num_slots=2, max_len=32),
                             replicas=2)
        for uid in range(4):
            router.submit(Request(uid=uid,
                                  prompt=np.array([2, 7, 1], np.int32),
                                  max_new_tokens=2))
        router.run_until_drained()
        assert sorted(r.uid for r in router.completed) == [0, 1, 2, 3]
        # round-robin: even uids on replica 0, odd on replica 1
        assert sorted(r.uid for r in router.replicas[0].completed) == [0, 2]
        snap = router.metrics.snapshot(meta=False)
        gauges = {(e["name"], e["labels"].get("replica"))
                  for e in snap["gauges"]}
        assert ("serve_replica_slots_active", "0") in gauges
        assert ("serve_replica_tokens_per_second", "1") in gauges
        routed = [e for e in snap["counters"]
                  if e["name"] == "serve_router_requests_total"]
        assert routed and routed[0]["value"] == 4
        # per-replica families are labeled, token totals preserved
        toks = {e["labels"]["replica"]: e["value"]
                for e in snap["counters"] if e["name"] == "serve_tokens_total"}
        assert set(toks) == {"0", "1"} and sum(toks.values()) == 8

    def test_plan_conflict_rejected(self):
        from repro.core.sparse_linear import ExecPolicy
        from repro.serve import ServeConfig, make_engine
        from repro.sharding.plan import ShardingPlan
        cfg, model, params = self._model()
        with pytest.raises(ValueError):
            make_engine(model, params, ServeConfig(num_slots=2, max_len=32),
                        plan=ShardingPlan(tp=2),
                        policy=ExecPolicy(plan=ShardingPlan(pp=2)))


# ---------------------------------------------------------------------------
# Multi-device paths (subprocess with forced host devices)
# ---------------------------------------------------------------------------

_TP_IDENTITY = r"""
import numpy as np, jax
from repro.configs.base import get_arch
from repro.models.families import build_model
from repro.launch.serve import run_serve
from repro.sharding.plan import ShardingPlan
from repro.core.sparsity import PackedWeight

cfg = get_arch("stablelm_3b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
for layout in ("xwT", "block"):
    base = run_serve(model, params, cfg.vocab_size, packed=True,
                     layout=layout, requests=3, max_new=6, seed=0)
    ref = {r.uid: r.output for r in base.completed}
    tp = run_serve(model, params, cfg.vocab_size, packed=True, layout=layout,
                   requests=3, max_new=6, seed=0, plan=ShardingPlan(tp=2))
    got = {r.uid: r.output for r in tp.completed}
    assert ref == got, (layout, ref, got)
    found = []
    def visit(t):
        if isinstance(t, PackedWeight):
            if t.shard_axis is not None:
                found.append(t)
        elif isinstance(t, dict):
            for v in t.values():
                visit(v)
    visit(tp.params)
    assert found, layout + ": nothing renumbered"
    for pw in found:
        for child in (pw.values, pw.indices):
            per = [s.data.nbytes for s in child.addressable_shards]
            assert len(per) == 2 and all(b < child.nbytes for b in per), \
                (layout, per, child.nbytes)
    print("IDENTICAL_" + layout, len(found))
"""

_PP_IDENTITY = r"""
import numpy as np, jax
from repro.configs.base import get_arch
from repro.models.families import build_model
from repro.launch.serve import run_serve
from repro.sharding.plan import ShardingPlan

cfg = get_arch("stablelm_3b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
base = run_serve(model, params, cfg.vocab_size, packed=True, layout="xwT",
                 requests=4, max_new=6, seed=0)
ref = {r.uid: r.output for r in base.completed}
pp = run_serve(model, params, cfg.vocab_size, packed=True, layout="xwT",
               requests=4, max_new=6, seed=0, plan=ShardingPlan(pp=2))
got = {r.uid: r.output for r in pp.completed}
assert ref == got, (ref, got)
print("IDENTICAL_pp", len(got))
"""

_PAGED_TP = r"""
import numpy as np, jax
from repro.configs.base import get_arch
from repro.models.families import build_model
from repro.launch.serve import run_serve
from repro.sharding.plan import ShardingPlan

cfg = get_arch("stablelm_3b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
kw = dict(packed=True, layout="block", requests=4, max_new=8, seed=0,
          paged=True, page_size=8, max_pages=8, scheduler="priority")
base = run_serve(model, params, cfg.vocab_size, **kw)
ref = {r.uid: r.output for r in base.completed}
tp = run_serve(model, params, cfg.vocab_size, plan=ShardingPlan(tp=2), **kw)
got = {r.uid: r.output for r in tp.completed}
assert ref == got, (ref, got)
pre = [e for e in tp.metrics.snapshot(meta=False)["counters"]
       if e["name"] == "serve_preempt_total"]
assert pre and pre[0]["value"] > 0, "arena never preempted; test is vacuous"
k = tp.state["caches"]["k"]
per = [s.data.nbytes for s in k.addressable_shards]
assert len(per) == 2 and all(b < k.nbytes for b in per), per
print("PAGED_TP_OK", pre[0]["value"])
"""


class TestMultiDevice:
    def test_tp2_token_identity_both_layouts(self):
        out = run_with_devices(_TP_IDENTITY, n_devices=2)
        assert "IDENTICAL_xwT" in out and "IDENTICAL_block" in out

    def test_pp2_token_identity(self):
        out = run_with_devices(_PP_IDENTITY, n_devices=2)
        assert "IDENTICAL_pp" in out

    def test_paged_tp2_sharded_arena_under_preemption(self):
        out = run_with_devices(_PAGED_TP, n_devices=2)
        assert "PAGED_TP_OK" in out
