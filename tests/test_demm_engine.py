"""Tests for the functional DeMM engine model + pruning schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.demm import (
    DeMMConfig,
    demm_spmm,
    demm_spmm_k_passes,
    multiply_reduce,
    read_ports,
)
from repro.core.pruning import (
    PruneSchedule,
    init_mask,
    masked_weight,
    maybe_update_mask,
    rigl_update_mask,
)
from repro.core.sparsity import (
    SparsityConfig,
    pack,
    random_sparse_dense,
    satisfies_pattern,
)

TOL = dict(rtol=1e-4, atol=1e-5)


def test_read_ports_select_rows():
    b = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    idx = jnp.asarray([[0, 3], [7, 7]], jnp.int32)
    rows = read_ports(b, idx)
    assert rows.shape == (2, 2, 4)
    np.testing.assert_allclose(rows[0, 1], np.asarray(b[3]))
    np.testing.assert_allclose(rows[1, 0], np.asarray(b[7]))


def test_multiply_reduce_adder_tree():
    rows = jnp.ones((2, 4, 8))
    vals = jnp.asarray([[1.0, 2.0, 3.0, 4.0], [0.0, 0.0, 0.0, 0.0]])
    out = multiply_reduce(rows, vals)
    np.testing.assert_allclose(out[0], 10.0 * np.ones(8))
    np.testing.assert_allclose(out[1], np.zeros(8))


@pytest.mark.parametrize("n,m,groups", [(1, 4, 2), (2, 16, 4), (8, 128, 2)])
def test_engine_matches_dense(n, m, groups):
    rng = np.random.default_rng(n + m)
    cfg = SparsityConfig(n, m)
    a = random_sparse_dense(rng, 32, groups * m, cfg)
    b = rng.standard_normal((groups * m, 48)).astype(np.float32)
    p = pack(jnp.asarray(a), cfg)
    np.testing.assert_allclose(np.asarray(demm_spmm(p, jnp.asarray(b))),
                               a @ b, **TOL)


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_k_reconfiguration_equivalence(k):
    """Paper §II-B: a DeMM(N,M,·,k) engine computes the kN:M pattern in k
    passes with identical results."""
    rng = np.random.default_rng(k)
    cfg = SparsityConfig(8, 64)
    a = random_sparse_dense(rng, 16, 128, cfg)
    b = rng.standard_normal((128, 32)).astype(np.float32)
    p = pack(jnp.asarray(a), cfg)
    np.testing.assert_allclose(
        np.asarray(demm_spmm_k_passes(p, jnp.asarray(b), k=k)), a @ b, **TOL)


def test_demm_config_supports():
    eng = DeMMConfig(n=8, m=128, c=64, k=8)
    assert eng.multipliers == 512  # the paper's resource-equalized setup
    assert eng.supports(SparsityConfig(8, 128))
    assert eng.supports(SparsityConfig(16, 128))   # 16:128 == 2x8:128
    assert eng.supports(SparsityConfig(64, 128))   # 1:2-equivalent
    assert not eng.supports(SparsityConfig(8, 256))  # different M
    assert not eng.supports(SparsityConfig(65, 128))  # beyond k*N


def test_straight_through_gradients():
    cfg = SparsityConfig(1, 4)
    w = jnp.asarray([[1.0, 2.0, 0.5, 0.25]])

    def loss(w):
        return jnp.sum(masked_weight(w, cfg) * 3.0)

    g = np.asarray(jax.grad(loss)(w))
    # straight-through: gradient reaches masked-out weights too
    np.testing.assert_allclose(g, 3.0 * np.ones((1, 4)))
    # forward is masked
    np.testing.assert_allclose(np.asarray(masked_weight(w, cfg)),
                               [[0.0, 2.0, 0.0, 0.0]])


def test_rigl_update_keeps_pattern_and_regrows():
    cfg = SparsityConfig(2, 8)
    sched = PruneSchedule(cfg=cfg, update_every=1, regrow_fraction=0.5)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    mask = init_mask(w, cfg)
    # gradient strongly favours position 0 of each group
    grad = jnp.zeros((4, 16)).at[:, 0].set(100.0).at[:, 8].set(100.0)
    new_mask = rigl_update_mask(w, mask, grad, sched)
    nm = np.asarray(new_mask).reshape(4, 2, 8)
    assert np.all(nm.sum(-1) == 2)           # exactly N per group
    assert np.all(nm[:, :, 0])               # regrown at max-gradient slot


def test_maybe_update_mask_schedule():
    cfg = SparsityConfig(1, 4)
    sched = PruneSchedule(cfg=cfg, update_every=10, stop_update_after=100)
    w = jnp.asarray(np.random.default_rng(1).standard_normal((2, 8)),
                    jnp.float32)
    mask = init_mask(w, cfg)
    grad = jnp.ones_like(w)
    same = maybe_update_mask(jnp.asarray(7), w, mask, grad, sched)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(mask))
    frozen = maybe_update_mask(jnp.asarray(110), w, mask, grad, sched)
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(mask))


def test_sparse_linear_roundtrip_train_to_serve():
    from repro.core import sparse_linear as sl
    from repro.core.sparse_linear import ExecPolicy
    from repro.core.sparsity import PackedWeight

    cfg = SparsityConfig(2, 16)
    key = jax.random.PRNGKey(0)
    params = sl.init_sparse(key, 64, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y_masked = sl.apply_masked(params, x, cfg)
    packed = sl.pack_params(params, cfg)
    assert isinstance(packed, PackedWeight)
    for backend in ("reference", "pallas_interpret"):
        y_packed = sl.apply(packed, x, ExecPolicy(mode="packed",
                                                  backend=backend))
        np.testing.assert_allclose(np.asarray(y_masked), np.asarray(y_packed),
                                   rtol=1e-3, atol=1e-3)
    # the packed weight satisfies the pattern by construction
    assert satisfies_pattern(packed.to_dense(), cfg)


def test_sparse_linear_k_reconfiguration_survives_pack():
    """Regression: a k>1 SparsityConfig must survive pack -> apply (the old
    dict convention rebuilt SparsityConfig(n, m, 1), silently dropping the
    paper's k-reconfiguration)."""
    from repro.core import sparse_linear as sl
    from repro.core.sparse_linear import ExecPolicy

    cfg = SparsityConfig(2, 32, k=2)   # 4:32 served as 2 passes of 2:32
    params = sl.init_sparse(jax.random.PRNGKey(0), 64, 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    pw = sl.pack_params(params, cfg)
    assert pw.cfg == cfg and pw.cfg.k == 2
    assert pw.values.shape[-1] == cfg.n_effective == 4
    y_masked = sl.apply_masked(params, x, cfg)
    y_packed = sl.apply(pw, x, ExecPolicy(mode="packed"))
    np.testing.assert_allclose(np.asarray(y_masked), np.asarray(y_packed),
                               rtol=1e-3, atol=1e-3)
