"""``repro.sparsetrain`` tests: packed-vs-dense gradient parity for every
layout (xwT, block, q8 — ragged and scan-stacked shapes), the QAT↔serve
numerics contract, gradual-sparsification schedules, and checkpoint resume
mid-schedule preserving mask/scale state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core import sparse_linear as sl
from repro.core.sparse_linear import ExecPolicy
from repro.core.sparsity import (
    SparsityConfig,
    pack_block,
    pack_block_stacked,
    prune,
    random_sparse_dense,
    satisfies_pattern,
)
from repro.data.pipeline import DataConfig
from repro.models.families import build_model
from repro.optim import adamw
from repro.quant import quantize_packed
from repro.sparsetrain import (
    SparseTrainRecipe,
    SparseTrainer,
    anneal_schedule,
    apply_mask_tree,
    build_masks,
    fake_quant_weight,
    init_mask_state,
    parse_pattern,
    parse_schedule,
    update_mask_state,
)
from repro.sparsetrain.masks import SparsifySchedule, node_phase_cfg
from repro.train.fault_tolerance import (
    SupervisorConfig,
    TrainingSupervisor,
    inject_failure_once,
)
from repro.train.train_loop import make_train_step

CFG = SparsityConfig(2, 16)
PACKED = ExecPolicy(mode="packed")


def _data(key=0, o=24, k=64, b=5):
    """Ragged (non-tile-multiple) shapes on purpose."""
    rng = np.random.default_rng(key)
    w = jnp.asarray(random_sparse_dense(rng, o, k, CFG))
    x = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    dy = jnp.asarray(rng.standard_normal((b, o)), jnp.float32)
    return w, x, dy


def _dense_grads(w, x, dy):
    def loss(wd, xx):
        return jnp.sum(jnp.dot(xx, wd.T) * dy)

    return jax.grad(loss, argnums=(0, 1))(w, x)


# ---------------------------------------------------------------------------
# Gradient parity: packed-vs-dense for every layout (acceptance <= 1e-4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["xwT", "block"])
def test_float_packed_grad_parity(layout):
    w, x, dy = _data()
    pw = (pack_block(w, CFG, block_r=8) if layout == "block"
          else sl.pack_params({"w": w}, CFG))
    gw_d, gx_d = _dense_grads(w, x, dy)

    gx = jax.grad(lambda xx: jnp.sum(sl.apply(pw, xx, PACKED) * dy))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-5)

    gv = jax.grad(lambda v: jnp.sum(
        sl.apply(pw.replace(values=v), x, PACKED) * dy))(pw.values)
    # the packed-weight gradient must equal the dense gradient gathered at
    # the packed coordinates — scatter it back to dense and compare on the
    # support
    g_dense = pw.replace(values=gv).to_dense()
    support = (pw.to_dense() != 0).astype(np.float32)
    np.testing.assert_allclose(np.asarray(g_dense),
                               np.asarray(gw_d * support),
                               rtol=1e-4, atol=1e-5)


def test_block_padded_slots_receive_no_gradient():
    """Under-full groups pad with zero values; their gradient must stay 0
    or fine-tuning would densify the pattern."""
    w, x, dy = _data(key=3)
    pw = pack_block(w, CFG, block_r=8)
    gv = jax.grad(lambda v: jnp.sum(
        sl.apply(pw.replace(values=v), x, PACKED) * dy))(pw.values)
    assert bool(jnp.all(jnp.where(pw.values == 0, gv == 0, True)))


@pytest.mark.parametrize("layout", ["xwT", "block"])
def test_stacked_packed_grad_parity(layout):
    """Scan-stacked weights (L, ...) — the model's per-layer slicing —
    propagate per-slice gradients identical to the unstacked op."""
    rng = np.random.default_rng(7)
    ws = jnp.asarray(np.stack([random_sparse_dense(rng, 16, 32, CFG)
                               for _ in range(3)]))
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    if layout == "block":
        pw = pack_block_stacked(ws, CFG, block_r=8)
    else:
        from repro.launch.pack_tree import pack_tree
        from repro.core.sparsity import Static

        pw = pack_tree({"w": ws, "sparsity": Static(CFG)})

    def loss_stacked(values):
        def body(carry, pw_slice):
            return carry + jnp.sum(sl.apply(pw_slice, x, PACKED)), None

        out, _ = jax.lax.scan(body, 0.0, pw.replace(values=values))
        return out

    gv = jax.grad(loss_stacked)(pw.values)
    for i in range(3):
        slice_pw = jax.tree.map(lambda a: a[i], pw)
        gv_i = jax.grad(lambda v: jnp.sum(
            sl.apply(slice_pw.replace(values=v), x, PACKED)))(slice_pw.values)
        np.testing.assert_allclose(np.asarray(gv[i]), np.asarray(gv_i),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("granularity", ["per_row", "per_group"])
def test_q8_grad_dx_parity_and_scale_gradient(granularity):
    """Quantized xwT inside jax.grad: dx is exact against the dequantized
    dense weight; dL/dscales matches finite differences."""
    w, x, dy = _data(key=1)
    q = quantize_packed(sl.pack_params({"w": w}, CFG),
                        granularity=granularity)
    wd = q.to_dense()
    gx = jax.grad(lambda xx: jnp.sum(sl.apply(q, xx, PACKED) * dy))(x)
    gx_d = jax.grad(lambda xx: jnp.sum(jnp.dot(xx, wd.T) * dy))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-5)

    loss_s = lambda s: jnp.sum(sl.apply(q.replace(scales=s), x, PACKED) * dy)
    gs = jax.grad(loss_s)(q.scales)
    assert gs.shape == q.scales.shape
    idx = (0,) if granularity == "per_row" else (0, 1)
    eps = 1e-3
    fd = (loss_s(q.scales.at[idx].add(eps)) - loss_s(q.scales)) / eps
    assert float(gs[idx]) == pytest.approx(float(fd), rel=1e-2, abs=1e-2)


def test_block_q8_grad_dx_parity_and_scale_gradient():
    w, x, dy = _data(key=2, o=32)
    q = quantize_packed(pack_block(w, CFG, block_r=8))
    wd = q.to_dense()
    gx = jax.grad(lambda xx: jnp.sum(sl.apply(q, xx, PACKED) * dy))(x)
    gx_d = jax.grad(lambda xx: jnp.sum(jnp.dot(xx, wd.T) * dy))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-5)
    loss_s = lambda s: jnp.sum(sl.apply(q.replace(scales=s), x, PACKED) * dy)
    gs = jax.grad(loss_s)(q.scales)
    assert gs.shape == q.scales.shape
    eps = 1e-3
    fd = (loss_s(q.scales.at[0, 0, 1].add(eps)) - loss_s(q.scales)) / eps
    assert float(gs[0, 0, 1]) == pytest.approx(float(fd), rel=1e-2, abs=1e-2)


# ---------------------------------------------------------------------------
# QAT <-> serve numerics contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("granularity", ["per_row", "per_group"])
def test_fake_quant_matches_served_quantization(granularity):
    """STE fake-quant of the masked dense weight == dequantized image of
    the packed int8 serving weight, bit for bit (same amax grid, same
    rounding, same clip)."""
    rng = np.random.default_rng(5)
    w = prune(jnp.asarray(rng.standard_normal((24, 64)), jnp.float32), CFG)
    fq = fake_quant_weight(w, m=CFG.m, granularity=granularity)
    q = quantize_packed(sl.pack_params({"w": w}, CFG),
                        granularity=granularity)
    np.testing.assert_array_equal(np.asarray(fq), np.asarray(q.to_dense()))


def test_fake_quant_straight_through_gradient():
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    g = jax.grad(lambda ww: jnp.sum(fake_quant_weight(ww)))(w)
    np.testing.assert_array_equal(np.asarray(g), np.ones_like(g))


def test_fake_quant_error_bound():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    fq = fake_quant_weight(w)
    bound = jnp.max(jnp.abs(w), axis=-1, keepdims=True) / 127 * 0.5
    assert bool(jnp.all(jnp.abs(fq - w) <= bound * (1 + 1e-6)))


# ---------------------------------------------------------------------------
# Schedules and mask state
# ---------------------------------------------------------------------------

def test_parse_pattern_and_schedule():
    assert parse_pattern("8:128") == SparsityConfig(8, 128, 1)
    assert parse_pattern("8:128:2") == SparsityConfig(8, 128, 2)
    with pytest.raises(ValueError, match="cannot parse"):
        parse_pattern("8")

    sched = parse_schedule("dense@0,2:32@4,2:16@10", 20, update_every=3)
    assert [p.start for p in sched.phases] == [0, 4, 10]
    assert sched.phases[0].cfg is None
    assert sched.cfg_at(0) is None
    assert sched.cfg_at(5) == SparsityConfig(2, 32)
    assert sched.cfg_at(100) == SparsityConfig(2, 16)
    assert sched.phase_index(9) == 1 and sched.phase_index(10) == 2

    auto = parse_schedule("8:128", 100)
    assert auto.phases[0].cfg is None
    assert auto.phases[1].cfg == SparsityConfig(8, 256, 1)  # coarse N:2M
    assert auto.phases[-1].cfg == SparsityConfig(8, 128, 1)
    assert auto.freeze_after == 90

    # round-trips through the canonical spec string
    assert parse_schedule("8:128:2", 100).phases[-1].cfg.k == 2


def test_schedule_validation():
    from repro.sparsetrain import SparsifyPhase

    with pytest.raises(ValueError, match="start at step 0"):
        SparsifySchedule(phases=(SparsifyPhase(5, CFG),))
    with pytest.raises(ValueError, match="final phase"):
        parse_schedule("dense@0", 10)
    with pytest.raises(ValueError, match="increasing"):
        parse_schedule("dense@0,2:16@5,2:32@5", 10)


def test_node_phase_cfg_resolution():
    node = SparsityConfig(2, 16)
    # dense phase
    assert node_phase_cfg(None, node, 64, False) is None
    # final phase always snaps to the node's own (serving) config
    assert node_phase_cfg(SparsityConfig(8, 128), node, 64, True) == node
    # divisible: phase config applies verbatim
    assert node_phase_cfg(SparsityConfig(2, 32), node, 64, False) == \
        SparsityConfig(2, 32)
    # not divisible: density-matched at the node's native group size
    got = node_phase_cfg(SparsityConfig(3, 48), node, 64, False)
    assert got.m == node.m and got.n_effective == 1  # round(16 * 3/48)


def test_build_masks_phases_and_pattern():
    cfg = get_arch("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = parse_schedule("dense@0,2:32@2,2:16@5", 12, update_every=2)

    dense_masks = build_masks(params, sched, 0)
    leaves = [m for m in jax.tree.leaves(dense_masks) if m is not None]
    assert leaves and all(bool(jnp.all(m)) for m in leaves)

    final_masks = build_masks(params, sched, 2)

    def check(node, masks):
        if isinstance(node, dict):
            if "w" in node and sl.node_sparsity(node) is not None:
                ncfg = sl.node_sparsity(node)
                masked = node["w"] * masks.astype(node["w"].dtype)
                flat = masked.reshape(-1, masked.shape[-1])
                assert bool(satisfies_pattern(flat, ncfg))
                return
            for k in node:
                check(node[k], masks[k] if isinstance(masks, dict) else None)

    check(params, final_masks)


def test_update_mask_state_cadence_and_freeze():
    cfg = get_arch("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = parse_schedule("dense@0,2:32@2,2:16@5", 20, update_every=3)
    sched = SparsifySchedule(phases=sched.phases, update_every=3,
                             freeze_after=9)
    state = init_mask_state(params, sched, 0)
    assert int(state["phase"]) == 0

    state, changed = update_mask_state(params, state, sched, 1)
    assert not changed                       # dense phase, nothing to do
    state, changed = update_mask_state(params, state, sched, 2)
    assert changed and int(state["phase"]) == 1   # phase transition
    state, changed = update_mask_state(params, state, sched, 4)
    assert not changed                       # update_every=3 not yet due
    state, changed = update_mask_state(params, state, sched, 5)
    assert changed and int(state["phase"]) == 2   # next transition
    state, changed = update_mask_state(params, state, sched, 8)
    assert changed                           # within-phase refresh
    state, changed = update_mask_state(params, state, sched, 11)
    assert not changed                       # frozen at 9
    # ...but a (hypothetical) later phase transition still applies while
    # frozen: simulate by rewinding the recorded phase.
    state["phase"] = jnp.asarray(1, jnp.int32)
    state, changed = update_mask_state(params, state, sched, 12)
    assert changed and int(state["phase"]) == 2


# ---------------------------------------------------------------------------
# Train-step integration + checkpoint resume mid-schedule
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_train_step_with_masks_and_qat(small_model):
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    opt = adamw.init(opt_cfg, params)
    sched = parse_schedule("2:16", 10)
    masks = init_mask_state(params, sched, 6)["masks"]   # sparse phase
    step = jax.jit(make_train_step(model, opt_cfg, fake_quant="int8"))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
    }
    losses = []
    for i in range(6):
        params, opt, m = step(params, opt, batch, i, masks)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


def test_masks_require_premask_mode(small_model):
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=4, warmup_steps=1)
    opt = adamw.init(opt_cfg, params)
    sched = parse_schedule("2:16", 4)
    masks = init_mask_state(params, sched, 3)["masks"]
    step = make_train_step(model, opt_cfg, premask=False)
    with pytest.raises(ValueError, match="premask"):
        step(params, opt, {"tokens": jnp.zeros((2, 8), jnp.int32),
                           "targets": jnp.zeros((2, 8), jnp.int32)},
             0, masks)


def _run_sparse_training(model, params, opt_cfg, data_cfg, ckpt_dir, steps,
                         injector=None, qat=None):
    sched = parse_schedule("dense@0,2:32@2,2:16@5", steps, update_every=3)
    trainer = SparseTrainer(model, opt_cfg,
                            SparseTrainRecipe(schedule=sched, qat=qat))
    trainer.init_state(params)
    opt = adamw.init(opt_cfg, params)
    sup = TrainingSupervisor(
        SupervisorConfig(ckpt_dir=ckpt_dir, ckpt_every=4),
        trainer.train_step, data_cfg, extra_state=trainer)
    p, o, m, restarts = sup.run(params, opt, steps,
                                failure_injector=injector)
    return p, trainer, restarts


def test_resume_mid_schedule_bitwise(tmp_path, small_model):
    """A failure + restore mid-schedule reproduces the uninterrupted
    trajectory bitwise, mask state included (the checkpoint carries it)."""
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=12, warmup_steps=1)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4)

    p_ok, tr_ok, r_ok = _run_sparse_training(
        model, params, opt_cfg, data_cfg, str(tmp_path / "a"), 12)
    p_f, tr_f, r_f = _run_sparse_training(
        model, params, opt_cfg, data_cfg, str(tmp_path / "b"), 12,
        injector=inject_failure_once(9))
    assert r_ok == 0 and r_f == 1
    for a, b in zip(jax.tree.leaves(p_ok), jax.tree.leaves(p_f)):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # mask state (phase, refresh step, every mask) is identical too
    assert int(tr_ok.state["phase"]) == int(tr_f.state["phase"])
    assert int(tr_ok.state["last_update"]) == int(tr_f.state["last_update"])
    for a, b in zip(jax.tree.leaves(tr_ok.state["masks"]),
                    jax.tree.leaves(tr_f.state["masks"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_with_different_schedule_raises(tmp_path, small_model):
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=6, warmup_steps=1)
    sched_a = parse_schedule("2:16", 6)
    trainer_a = SparseTrainer(model, opt_cfg,
                              SparseTrainRecipe(schedule=sched_a))
    trainer_a.init_state(params)
    sched_b = parse_schedule("2:16", 6, update_every=7)
    trainer_b = SparseTrainer(model, opt_cfg,
                              SparseTrainRecipe(schedule=sched_b))
    with pytest.raises(ValueError, match="schedule"):
        trainer_b.load_extra_state(trainer_a.extra_state())


def test_finalize_bakes_masks_and_packs(small_model):
    """finalize() makes the weights satisfy their patterns exactly, so
    they pack losslessly and apply identically masked vs packed."""
    cfg, model, params = small_model
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=4, warmup_steps=1)
    sched = parse_schedule("2:16", 4)
    trainer = SparseTrainer(model, opt_cfg,
                            SparseTrainRecipe(schedule=sched))
    trainer.init_state(params, step=3)       # already in the final phase
    baked = trainer.finalize(params)
    from repro.launch.train import verify_final_masks

    assert verify_final_masks(baked) > 0
